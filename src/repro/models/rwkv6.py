"""RWKV-6 "Finch" backbone (arXiv:2404.05892): token-shift data-dependent
mixing, per-channel data-dependent decay linear attention (WKV6), and
squared-ReLU channel mix.

Per head (key/value dims P=64) the WKV recurrence is
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
with w_t = exp(-exp(ww_t)) data-dependent per channel.

Training uses a chunked form where every decay factor is an
exp(non-positive difference) of cumulative log-decays — numerically safe
for arbitrarily strong decay; a sequential oracle (`wkv6_sequential`)
backs the tests. This is the arch-pool cousin of the paper's GRU
accelerator: a weights-resident recurrence over streaming features
(DESIGN.md §4).

TP: heads partition the channel dim; r/k/v/g are column-parallel,
output row-parallel; the tiny ddlerp/decay LoRAs are replicated.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import cross_entropy_loss, dense_init, rms_norm

Params = Dict[str, Any]

_MIX_NAMES = ("w", "k", "v", "r", "g")
_LORA_DIM = 32
_DECAY_LORA = 64


def rwkv6_block_init(key, cfg) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 16)
    p: Params = {
        "ln1": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.zeros((d,), jnp.float32),
        # token-shift ddlerp
        "mix_x": jnp.zeros((d,), jnp.float32),
        "mix_base": jnp.zeros((len(_MIX_NAMES), d), jnp.float32),
        "mix_w1": dense_init(ks[0], (d, len(_MIX_NAMES) * _LORA_DIM)),
        "mix_w2": dense_init(
            ks[1], (len(_MIX_NAMES), _LORA_DIM, d), fan_in=_LORA_DIM
        ),
        # time-mix projections
        "w_r": dense_init(ks[2], (d, d)),
        "w_k": dense_init(ks[3], (d, d)),
        "w_v": dense_init(ks[4], (d, d)),
        "w_g": dense_init(ks[5], (d, d)),
        "w_o": dense_init(ks[6], (d, d)),
        # data-dependent decay: ww = base + tanh(x W1) W2
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "decay_w1": dense_init(ks[7], (d, _DECAY_LORA)),
        "decay_w2": dense_init(ks[8], (_DECAY_LORA, d), fan_in=_DECAY_LORA),
        "bonus_u": jnp.zeros((d,), jnp.float32),  # per-channel "faaaa"
        "ln_x": jnp.zeros((d,), jnp.float32),  # per-head group norm scale
        # channel mix
        "cm_mix_k": jnp.zeros((d,), jnp.float32),
        "cm_mix_r": jnp.zeros((d,), jnp.float32),
        "cm_w_k": dense_init(ks[9], (d, cfg.d_ff)),
        "cm_w_v": dense_init(ks[10], (cfg.d_ff, d), fan_in=cfg.d_ff),
        "cm_w_r": dense_init(ks[11], (d, d)),
    }
    return p


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray] = None):
    """shift right by one along time; `last` is the carry for streaming."""
    first = (
        jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    )
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(p, x, xs):
    """Data-dependent interpolation producing the 5 mixed inputs."""
    dx = xs - x
    xxx = x + dx * p["mix_x"].astype(x.dtype)
    lora = jnp.tanh(xxx @ p["mix_w1"].astype(x.dtype))
    lora = lora.reshape(*x.shape[:2], len(_MIX_NAMES), _LORA_DIM)
    deltas = jnp.einsum(
        "blmr,mrd->blmd", lora, p["mix_w2"].astype(x.dtype)
    )
    mixed = []
    for i, _ in enumerate(_MIX_NAMES):
        m = p["mix_base"][i].astype(x.dtype) + deltas[:, :, i]
        mixed.append(x + dx * m)
    return mixed  # [x_w, x_k, x_v, x_r, x_g]


def wkv6_chunked(r, k, v, logw, u, chunk):
    """Chunked WKV6. r/k/v (B, L, H, P), logw (B, L, H, P) (<= 0),
    u (H, P). Returns (y (B, L, H, P), final state (B, H, P, P))."""
    b, l, h, p = r.shape
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        # zero-pad: k=v=0 adds nothing to the state, logw=0 leaves it
        # untouched, so the final state stays exact; padded outputs are
        # sliced off below.
        zero = lambda t: jnp.concatenate(  # noqa: E731
            [t, jnp.zeros((b, pad, h, p), t.dtype)], axis=1
        )
        r, k, v = zero(r), zero(k), zero(v)
        logw = jnp.concatenate(
            [logw, jnp.zeros((b, pad, h, p), logw.dtype)], axis=1
        )
    nc = (l + pad) // q
    rs = r.reshape(b, nc, q, h, p)
    ks_ = k.reshape(b, nc, q, h, p)
    vs = v.reshape(b, nc, q, h, p)
    lw = logw.reshape(b, nc, q, h, p).astype(jnp.float32)

    il = jnp.cumsum(lw, axis=2)  # inclusive
    el = il - lw  # exclusive: decay applied to state BEFORE step t
    total = il[:, :, -1]  # (b, nc, h, p)

    # intra-chunk: y_t gets k_j (j < t) with decay prod_{s=j+1..t-1} w_s
    #            = exp(el_t - il_j); plus the bonus u*k_t at j == t.
    ratio = jnp.exp(
        jnp.clip(
            el[:, :, :, None, :, :] - il[:, :, None, :, :, :], -60.0, 0.0
        )
    )  # (b, nc, t, j, h, p)
    tri = jnp.tril(jnp.ones((q, q), bool), -1)
    ratio = jnp.where(tri[None, None, :, :, None, None], ratio, 0.0)
    scores = jnp.einsum(
        "bcthp,bcjhp,bctjhp->bctjh",
        rs.astype(jnp.float32),
        ks_.astype(jnp.float32),
        ratio,
    )
    diag_sc = jnp.einsum(
        "bcthp,hp,bcthp->bcth",
        rs.astype(jnp.float32),
        u.astype(jnp.float32),
        ks_.astype(jnp.float32),
    )
    y_intra = jnp.einsum(
        "bctjh,bcjhp->bcthp", scores.astype(r.dtype), vs
    ) + diag_sc[..., None].astype(r.dtype) * vs

    # chunk-local end state: sum_j exp(total - il_j) k_j v_j^T
    decay_to_end = jnp.exp(jnp.clip(total[:, :, None] - il, -60.0, 0.0))
    s_local = jnp.einsum(
        "bcjhp,bcjhv->bhcpv",
        (ks_.astype(jnp.float32) * decay_to_end).astype(r.dtype),
        vs,
    )  # note axes: (b, h, nc, p, v) for the scan below
    s_local = jnp.moveaxis(s_local, 2, 1)  # (b, nc, h, p, v)

    def step(s_prev, inputs):
        s_loc, tot = inputs
        s_new = s_prev * jnp.exp(tot)[..., None].astype(s_prev.dtype) + s_loc
        return s_new, s_prev

    s0 = jnp.zeros((b, h, p, p), r.dtype)
    s_final, s_prevs = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (b, nc, h, p, v)

    # inter-chunk: y_t += (r_t * exp(el_t)) . S_chunk_start
    y_inter = jnp.einsum(
        "bcthp,bchpv->bcthv",
        (rs.astype(jnp.float32) * jnp.exp(el)).astype(r.dtype),
        s_prevs,
    )
    y = (y_intra + y_inter).reshape(b, l + pad, h, p)[:, :l]
    return y, s_final


def wkv6_sequential(r, k, v, logw, u):
    """Oracle: direct recurrence."""
    b, l, h, p = r.shape

    def step(s, inputs):
        r_t, k_t, v_t, lw_t = inputs
        kv = jnp.einsum("bhp,bhv->bhpv", k_t, v_t)
        y = jnp.einsum(
            "bhp,bhpv->bhv", r_t, s + u[None, :, :, None] * kv
        )
        s = s * jnp.exp(lw_t)[..., None] + kv
        return s, y

    s0 = jnp.zeros((b, h, p, p), r.dtype)
    _, ys = jax.lax.scan(
        step,
        s0,
        tuple(
            jnp.moveaxis(t, 1, 0)
            for t in (r, k, v, logw.astype(r.dtype))
        ),
    )
    return jnp.moveaxis(ys, 0, 1)


def _time_mix_pre(p, x, cfg, shift_state=None):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    n_heads = d // hd
    xs = _token_shift(x, shift_state)
    x_w, x_k, x_v, x_r, x_g = _ddlerp(p, x, xs)
    r = (x_r @ p["w_r"].astype(x.dtype)).reshape(*x.shape[:2], n_heads, hd)
    k = (x_k @ p["w_k"].astype(x.dtype)).reshape(*x.shape[:2], n_heads, hd)
    v = (x_v @ p["w_v"].astype(x.dtype)).reshape(*x.shape[:2], n_heads, hd)
    g = jax.nn.silu(x_g @ p["w_g"].astype(x.dtype))
    ww = p["decay_base"].astype(jnp.float32) + (
        jnp.tanh(x_w @ p["decay_w1"].astype(x.dtype))
        @ p["decay_w2"].astype(x.dtype)
    ).astype(jnp.float32)
    logw = -jnp.exp(ww)  # <= 0, per channel
    logw = logw.reshape(*x.shape[:2], n_heads, hd)
    u = p["bonus_u"].reshape(n_heads, hd)
    return r, k, v, g, logw, u, x[:, -1, :]


def _time_mix_post(p, y, g, cfg):
    b, l = y.shape[:2]
    d = cfg.d_model
    # per-head group norm
    y32 = y.astype(jnp.float32)
    mean = y32.mean(-1, keepdims=True)
    var = y32.var(-1, keepdims=True)
    yn = (y32 - mean) * jax.lax.rsqrt(var + 64e-5)
    yn = yn.reshape(b, l, d) * (1.0 + p["ln_x"].astype(jnp.float32))
    out = (yn.astype(g.dtype) * g) @ p["w_o"].astype(g.dtype)
    return out


def _channel_mix(p, x, shift_state=None):
    xs = _token_shift(x, shift_state)
    dx = xs - x
    x_k = x + dx * p["cm_mix_k"].astype(x.dtype)
    x_r = x + dx * p["cm_mix_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(x_k @ p["cm_w_k"].astype(x.dtype)))
    vv = k @ p["cm_w_v"].astype(x.dtype)
    return jax.nn.sigmoid(x_r @ p["cm_w_r"].astype(x.dtype)) * vv, x[:, -1, :]


def rwkv6_block_apply(p, x, cfg):
    """Full-sequence block. Returns (x, states) where states =
    (tm_shift, wkv_state, cm_shift) for streaming handoff."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    r, k, v, g, logw, u, tm_shift = _time_mix_pre(p, h, cfg)
    y, s_final = wkv6_chunked(r, k, v, logw, u, cfg.ssm.chunk)
    x = x + _time_mix_post(p, y, g, cfg)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    cm_out, cm_shift = _channel_mix(p, h2)
    return x + cm_out, (tm_shift, s_final, cm_shift)


def rwkv6_block_decode(p, x, cfg, state):
    """One-token decode. state = (tm_shift (B,d), wkv (B,H,P,P),
    cm_shift (B,d))."""
    tm_shift, s, cm_shift = state
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    r, k, v, g, logw, u, tm_new = _time_mix_pre(p, h, cfg, tm_shift)
    r1, k1, v1, lw1 = (t[:, 0] for t in (r, k, v, logw))
    kv = jnp.einsum("bhp,bhv->bhpv", k1, v1)
    y = jnp.einsum(
        "bhp,bhpv->bhv", r1, s + u[None, :, :, None].astype(x.dtype) * kv
    )[:, None]
    s_new = s * jnp.exp(lw1)[..., None].astype(s.dtype) + kv
    x = x + _time_mix_post(p, y, g, cfg)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    cm_out, cm_new = _channel_mix(p, h2, cm_shift)
    return x + cm_out, (tm_new, s_new, cm_new)


# --------------------------------------------------------------------------
# backbone API
# --------------------------------------------------------------------------

def init_params(key, cfg, mesh_ctx=None) -> Params:
    keys = jax.random.split(key, 4)
    d, v = cfg.d_model, cfg.vocab_padded
    layer_keys = jax.random.split(keys[1], cfg.n_layers)
    params = {
        "embed": dense_init(keys[0], (v, d), fan_in=d),
        "head": dense_init(keys[2], (d, v)),
        "final_norm": jnp.zeros((d,), jnp.float32),
        "layers": jax.vmap(lambda k: rwkv6_block_init(k, cfg))(layer_keys),
    }
    return jax.tree.map(lambda l: l.astype(cfg.activation_dtype), params)


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    return jax.checkpoint(fn)


def forward(params, batch, cfg, mesh_ctx=None):
    x = params["embed"].astype(cfg.activation_dtype)[batch["tokens"]]
    if mesh_ctx is not None:
        x = mesh_ctx.constrain_hidden(x)

    def body(x, p):
        if mesh_ctx is not None:
            x = mesh_ctx.constrain_hidden(x)
        x, _ = rwkv6_block_apply(p, x, cfg)
        return x, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"].astype(x.dtype)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, mesh_ctx=None):
    logits, _ = forward(params, batch, cfg, mesh_ctx)
    return cross_entropy_loss(logits, batch["labels"], cfg.final_softcap)


def init_cache(cfg, batch: int, max_len: int, mesh_ctx=None):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    n_heads = d // hd
    dt = cfg.activation_dtype
    L = cfg.n_layers
    return {
        "tm_shift": jnp.zeros((L, batch, d), dt),
        "wkv": jnp.zeros((L, batch, n_heads, hd, hd), dt),
        "cm_shift": jnp.zeros((L, batch, d), dt),
    }


def prefill(params, batch, cfg, mesh_ctx=None, max_len=None):
    x = params["embed"].astype(cfg.activation_dtype)[batch["tokens"]]
    if mesh_ctx is not None:
        x = mesh_ctx.constrain_hidden(x)

    def body(x, p):
        if mesh_ctx is not None:
            x = mesh_ctx.constrain_hidden(x)
        x, (tm, s, cm) = rwkv6_block_apply(p, x, cfg)
        return x, {"tm_shift": tm, "wkv": s, "cm_shift": cm}

    x, cache = jax.lax.scan(body, x, params["layers"])
    h = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = (h @ params["head"].astype(h.dtype))[:, 0, :]
    return logits, cache


def decode_step(params, cache, cache_len, batch, cfg, mesh_ctx=None):
    x = params["embed"].astype(cfg.activation_dtype)[batch["tokens"]]
    if mesh_ctx is not None:
        x = mesh_ctx.constrain_hidden(x)

    def body(x, inputs):
        p, c = inputs
        if mesh_ctx is not None:
            x = mesh_ctx.constrain_hidden(x)
        x, (tm, s, cm) = rwkv6_block_decode(
            p, x, cfg, (c["tm_shift"], c["wkv"], c["cm_shift"])
        )
        return x, {"tm_shift": tm, "wkv": s, "cm_shift": cm}

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["head"].astype(h.dtype))[:, 0, :]
    return logits, new_cache
