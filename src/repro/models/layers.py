"""Shared building blocks for all assigned architectures.

Parameter trees are plain nested dicts of jnp arrays; scanned layer
stacks carry a leading (n_steps,) axis. Initializers take an explicit key
and return float32 masters cast to the activation dtype by the caller
(training keeps fp32 masters in the optimizer, not in the model).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "softcap",
    "rope",
    "apply_rope",
    "dense_init",
    "mlp_init",
    "mlp_apply",
    "cross_entropy_loss",
]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    """RMSNorm with the (1 + scale) parameterization (Gemma/LLaMA style).

    Statistics in fp32 regardless of activation dtype.
    """
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions (...,) -> (cos, sin) each (..., head_dim/2), fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (..., S, H, D); cos/sin (..., S, D/2) — rotate pairs (split halves)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)  # broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def dense_init(key, shape, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, jnp.float32) * std


def mlp_init(key, d_model: int, d_ff: int, act: str):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff)),
        "w_down": dense_init(ks[1], (d_ff, d_model), fan_in=d_ff),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff))
    return p


def mlp_apply(p, x: jnp.ndarray, act: str):
    dt = x.dtype
    up = x @ p["w_up"].astype(dt)
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * up
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"].astype(dt), approximate=True) * up
    elif act == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(act)
    return h @ p["w_down"].astype(dt)


def cross_entropy_loss(
    logits: jnp.ndarray,  # (B, S, V)
    labels: jnp.ndarray,  # (B, S) int32
    final_cap: Optional[float] = None,
) -> jnp.ndarray:
    logits = softcap(logits, final_cap).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    return jnp.mean(logz - gold)
